"""Selection (paper Algorithm 2) invariants — unit + hypothesis property."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import selection as sel
from repro.core import units as units_lib
from repro.models import model as model_lib


def _index(params, cfg):
    return units_lib.build_unit_index(cfg, params)


def test_unit_index_counts(tiny_cfg, tiny_params):
    idx = _index(tiny_params, tiny_cfg)
    total_from_units = sum(idx.unit_sizes().values())
    total = sum(l.size for l in jax.tree.leaves(tiny_params))
    assert total_from_units == total == idx.total_params


def test_greedy_meets_budget(tiny_cfg, tiny_params):
    idx = _index(tiny_params, tiny_cfg)
    norms, visits = sel.NormTracker(), sel.VisitTracker()
    # seed norms so selection is score-driven
    for u in idx.unit_sizes():
        norms.norms[u] = hash(u) % 100 + 1.0
    scfg = sel.SelectorConfig(sparsity=0.9, policy="greedy",
                              always_active_leaves=("final_norm",))
    plan, q = sel.select(idx, norms, visits, scfg)
    sizes = idx.unit_sizes()
    sigma = sum(sizes[u] for u in plan.selected_labels())
    n_s = (1 - 0.9) * idx.total_params
    assert sigma >= n_s, "greedy must accumulate at least the budget"
    assert 0 < q <= 1
    assert abs(q * sigma - n_s) / n_s < 0.05  # q recovers the exact budget


def test_greedy_picks_largest_norms(tiny_cfg, tiny_params):
    idx = _index(tiny_params, tiny_cfg)
    norms, visits = sel.NormTracker(), sel.VisitTracker()
    row_units = [f"{s.sid}/g{g}" for s in idx.stacks
                 for g in range(s.n_rows)]
    for i, u in enumerate(row_units):
        norms.norms[u] = float(i)            # later rows have larger norms
    for li in idx.leaves:
        norms.norms[li.name] = -1.0          # never pick leaves
    scfg = sel.SelectorConfig(
        sparsity=0.98, policy="greedy", use_visit_frequency=False,
        selectable_leaves=(), always_active_leaves=())
    plan, _ = sel.select(idx, norms, visits, scfg)
    chosen = [u for u in plan.selected_labels() if "/g" in u]
    chosen_norms = [norms.norms[u] for u in chosen]
    not_chosen = [norms.norms[u] for u in row_units if u not in chosen]
    assert min(chosen_norms) >= max(not_chosen)


def test_subopt_inverts(tiny_cfg, tiny_params):
    idx = _index(tiny_params, tiny_cfg)
    norms, visits = sel.NormTracker(), sel.VisitTracker()
    row_units = [f"{s.sid}/g{g}" for s in idx.stacks
                 for g in range(s.n_rows)]
    for i, u in enumerate(row_units):
        norms.norms[u] = float(i)
    scfg = sel.SelectorConfig(
        sparsity=0.98, policy="greedy", invert=True,
        use_visit_frequency=False, selectable_leaves=(),
        always_active_leaves=())
    plan, _ = sel.select(idx, norms, visits, scfg)
    chosen = [norms.norms[u] for u in plan.selected_labels() if "/g" in u]
    not_chosen = [norms.norms[u] for u in row_units
                  if u not in plan.selected_labels()]
    assert max(chosen) <= min(not_chosen)


def test_visit_frequency_prefers_unvisited(tiny_cfg, tiny_params):
    idx = _index(tiny_params, tiny_cfg)
    norms, visits = sel.NormTracker(), sel.VisitTracker()
    row_units = [f"{s.sid}/g{g}" for s in idx.stacks
                 for g in range(s.n_rows)]
    for u in row_units:
        norms.norms[u] = 100.0 if u.endswith("g0") else 1.0
    # visit g0 rows many times
    for _ in range(10):
        visits.record([u for u in row_units if u.endswith("g0")])
    scfg = sel.SelectorConfig(
        sparsity=0.99, policy="static", static_k_frac=0.25,
        selectable_leaves=(), always_active_leaves=())
    plan, _ = sel.select(idx, norms, visits, scfg)
    chosen = plan.selected_labels()
    # despite larger norms, heavily-visited g0 rows lose to unvisited ones
    assert not any(u.endswith("g0") for u in chosen if "/g" in u)


def test_static_policy_structure_stable(tiny_cfg, tiny_params):
    idx = _index(tiny_params, tiny_cfg)
    scfg = sel.SelectorConfig(sparsity=0.9, policy="static",
                              static_k_frac=0.5)
    norms, visits = sel.NormTracker(), sel.VisitTracker()
    plan1, _ = sel.select(idx, norms, visits, scfg)
    norms.norms = {u: float(np.random.rand()) for u in idx.unit_sizes()}
    plan2, _ = sel.select(idx, norms, visits, scfg)
    assert plan1.structure.k_per_stack == plan2.structure.k_per_stack


def test_cyclic_policy_cycles(tiny_cfg, tiny_params):
    idx = _index(tiny_params, tiny_cfg)
    scfg = sel.SelectorConfig(policy="cyclic", cyclic_block_rows=1,
                              selectable_leaves=(),
                              always_active_leaves=())
    seen = []
    for cursor in range(4):
        plan, _ = sel.select(idx, sel.NormTracker(), sel.VisitTracker(),
                             scfg, cursor=cursor)
        rows = [u for u in plan.selected_labels() if "/g" in u]
        assert len(rows) == 1
        seen.append(rows[0])
    assert len(set(seen)) == 4, "cyclic must visit distinct blocks"


@given(losses=st.lists(st.floats(0.1, 10.0), min_size=1, max_size=30),
       m=st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_should_reselect_property(losses, m):
    out = sel.should_reselect(losses, m)
    if len(losses) < m + 1:
        assert out is False
    else:
        window = losses[-m - 1:-1]
        assert out == (losses[-1] >= sum(window) / len(window))


@given(s=st.floats(0.5, 0.99), k_frac=st.floats(0.1, 1.0))
@settings(max_examples=20, deadline=None)
def test_static_budget_property(s, k_frac):
    from repro.configs.base import ModelConfig
    from repro.models import model as m_
    cfg = ModelConfig(name="t", family="dense", num_layers=4, d_model=16,
                      num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=64,
                      remat=False)
    params = m_.init_params(jax.random.PRNGKey(0), cfg)
    idx = units_lib.build_unit_index(cfg, params)
    scfg = sel.SelectorConfig(sparsity=s, policy="static",
                              static_k_frac=k_frac)
    plan, q = sel.select(idx, sel.NormTracker(), sel.VisitTracker(), scfg)
    # every stack keeps at least 1 and at most ceil(G * k_frac) rows
    for sid, k in plan.structure.k_per_stack:
        g = idx.stack(sid).n_rows
        assert 1 <= k <= max(1, math.ceil(g * k_frac))
    assert 0 < q <= 1
    # selected labels unique
    labels = plan.selected_labels()
    assert len(labels) == len(set(labels))
    # probe rows disjoint from selected rows
    for sid, pidx in plan.probe_idx.items():
        sel_rows = set(np.asarray(plan.stack_idx[sid]).tolist())
        assert not sel_rows & set(np.asarray(pidx).tolist())
