"""AdapterCache (HBM-resident delta tier) + adapter-aware scheduling:
LRU byte-budget eviction, q8 dequant-once promotion, capture-on-revert,
bit-identical cached vs uncached token streams, SLO turn budgets, the
aging anti-starvation bound, and the drained-turn budget regression."""
import jax
import numpy as np
import pytest

from repro.adapters import (AdapterCache, DeltaEntry, InMemoryRegistry,
                            SparseDelta, apply_delta, extract_delta,
                            quantize_delta)
from repro.runtime.serve_loop import DecodeServer, Request


from repro.adapters.testing import perturb_rows as _tuned


def _row_delta(i, rows=2, cols=64):
    return SparseDelta(
        {"w": DeltaEntry(idx=np.arange(rows, dtype=np.int32),
                         rows=np.full((rows, cols), float(i),
                                      np.float32))},
        meta={"adapter_id": f"a{i}"})


# --------------------------------------------------------------------- #
# AdapterCache unit behavior
# --------------------------------------------------------------------- #


def test_cache_lru_eviction_respects_byte_budget():
    deltas = {f"a{i}": _row_delta(i) for i in range(3)}
    nb = deltas["a0"].nbytes
    cache = AdapterCache(InMemoryRegistry(deltas), cache_bytes=2 * nb + 8)
    cache.get("a0")
    cache.get("a1")
    assert cache.cached_ids() == ["a0", "a1"]
    cache.get("a2")                      # over budget -> evict LRU (a0)
    assert cache.cached_ids() == ["a1", "a2"]
    assert cache.evictions == 1
    assert cache.resident_bytes() <= cache.cache_bytes
    cache.get("a1")                      # hit, LRU refresh
    assert cache.hits == 1 and cache.misses == 3
    cache.get("a0")                      # miss again -> evicts a2
    assert cache.cached_ids() == ["a1", "a0"]
    assert cache.stats()["h2d_bytes"] == 4 * nb  # every miss re-uploads


def test_cache_bypasses_delta_larger_than_budget():
    deltas = {"big": _row_delta(0, rows=16, cols=256)}
    cache = AdapterCache(InMemoryRegistry(deltas), cache_bytes=64)
    d = cache.get("big")
    assert d.entries["w"].rows.shape == (16, 256)
    assert cache.bypasses == 1 and cache.cached_ids() == []


def test_cache_q8_promotion_dequantizes_once():
    rng = np.random.RandomState(0)
    fp = SparseDelta(
        {"w": DeltaEntry(idx=np.asarray([1, 4], np.int32),
                         rows=rng.randn(2, 300).astype(np.float32))},
        meta={"adapter_id": "q"})
    q8 = quantize_delta(fp)
    assert q8.quantized
    cache = AdapterCache(InMemoryRegistry({"q": q8}),
                         cache_bytes=1 << 20)
    dev = cache.get("q")
    # promoted rows are the dequantized device values, not codec blocks
    assert not dev.quantized
    np.testing.assert_array_equal(
        np.asarray(dev.entries["w"].rows),
        np.asarray(q8.entries["w"].materialize_rows()))
    # the upload paid the QUANTIZED payload bytes only
    assert cache.stats()["h2d_bytes"] == q8.nbytes
    assert q8.nbytes < fp.nbytes
    dev2 = cache.get("q")                # hit: same buffers, no h2d
    assert dev2.entries["w"].rows is dev.entries["w"].rows
    assert cache.stats()["h2d_bytes"] == q8.nbytes


def test_cache_invalidated_when_adapter_republished():
    """Re-``put`` of an adapter bumps the registry's publish counter;
    the HBM tier must drop its stale copy instead of serving the old
    weights forever."""
    reg = InMemoryRegistry({"a": _row_delta(1)})
    cache = AdapterCache(reg, cache_bytes=1 << 20)
    cache.get("a")
    reg.put("a", _row_delta(7))
    d = cache.get("a")                   # stale drop -> re-promotion
    assert float(np.asarray(d.entries["w"].rows)[0, 0]) == 7.0
    assert cache.stale_drops == 1 and cache.misses == 2
    # a capture of the OLD rows (version moved while applied) is refused
    stale = cache._promote(_row_delta(1))
    stale.meta["registry_version"] = 0
    cache.drop("a")
    cache.put_back("a", stale)
    assert "a" not in cache and cache.captures == 0


def test_cache_put_back_captures_without_upload(tiny_params):
    """Revert's displaced rows are the adapter's exact resident values:
    put_back admits them with zero host->device traffic."""
    tuned = _tuned(tiny_params, rows=(0, 2), scale=0.5, seed=1)
    d = extract_delta(tiny_params, tuned, meta={"adapter_id": "A"})
    applied, disp = apply_delta(tiny_params, d)
    _, back = apply_delta(applied, disp, check_fingerprint=False)

    cache = AdapterCache(InMemoryRegistry({"A": d}), cache_bytes=1 << 24)
    cache.put_back("A", back)
    assert cache.captures == 1 and cache.stats()["h2d_bytes"] == 0
    dev = cache.get("A")                 # hit: no registry promotion
    assert cache.hits == 1 and cache.misses == 0
    for name, e in dev.entries.items():
        np.testing.assert_array_equal(
            np.asarray(e.rows),
            np.asarray(d.entries[name].materialize_rows()))


# --------------------------------------------------------------------- #
# cached serving: parity + bit-exact revert
# --------------------------------------------------------------------- #


def _mixed_requests(cfg, tenancy, new_tokens=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               3 + i % 3),
                    max_new_tokens=new_tokens, adapter_id=t)
            for i, t in enumerate(tenancy)]


def test_cached_serving_identical_and_revert_bit_exact(tiny_cfg,
                                                       tiny_params):
    tunedA = _tuned(tiny_params, rows=(0, 2), scale=0.8, seed=10)
    tunedB = _tuned(tiny_params, rows=(1, 3), scale=-0.6, seed=20)
    reg = InMemoryRegistry({
        "A": extract_delta(tiny_params, tunedA, meta={"adapter_id": "A"}),
        "B": extract_delta(tiny_params, tunedB, meta={"adapter_id": "B"}),
    })
    tenancy = ["A", "B", None, "B", "A", "B"]
    outs = {}
    for leg, kw in (("uncached", {}),
                    # budget of ONE delta: forces eviction/capture churn
                    ("cached", {"cache_bytes":
                                reg.get("A").nbytes + 64})):
        reqs = _mixed_requests(tiny_cfg, tenancy)
        srv = DecodeServer(tiny_cfg, tiny_params, batch_slots=2,
                           max_seq=64, registry=reg, steps_per_turn=2,
                           **kw)
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        assert all(r.done for r in reqs)
        outs[leg] = {r.rid: tuple(r.out) for r in reqs}
        if leg == "cached":
            assert srv.cache.misses >= 2    # both adapters promoted
            assert srv.cache.evictions >= 1  # tiny budget churned
            # eviction never breaks the bit-exact-revert invariant
            srv.restore_base()
            for a, b in zip(jax.tree.leaves(srv.params),
                            jax.tree.leaves(tiny_params)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))
    assert outs["cached"] == outs["uncached"]


# --------------------------------------------------------------------- #
# scheduler: turn budgets, SLO, aging, drain regression
# --------------------------------------------------------------------- #


def _two_group_server(tiny_cfg, tiny_params, **kw):
    tunedA = _tuned(tiny_params, rows=(0, 2), scale=0.7, seed=30)
    tunedM = _tuned(tiny_params, rows=(1, 3), scale=0.4, seed=40)
    reg = InMemoryRegistry({
        "A": extract_delta(tiny_params, tunedA, meta={"adapter_id": "A"}),
        "M": extract_delta(tiny_params, tunedM, meta={"adapter_id": "M"}),
    })
    return DecodeServer(tiny_cfg, tiny_params, registry=reg, **kw)


def test_turn_budget_scales_with_depth_and_slo(tiny_cfg, tiny_params):
    srv = _two_group_server(tiny_cfg, tiny_params, batch_slots=2,
                            max_seq=64, steps_per_turn=4)
    rng = np.random.default_rng(0)
    for i in range(8):       # deep majority queue
        srv.submit(Request(rid=i, prompt=rng.integers(0, 8, 3),
                           max_new_tokens=4, adapter_id="M"))
    srv.submit(Request(rid=8, prompt=rng.integers(0, 8, 3),
                       max_new_tokens=4, adapter_id="A"))
    groups = ["M", "A"]
    # deep queue amortizes its swap over a longer turn
    assert srv._turn_budget("M", groups) > srv._turn_budget("A", groups)
    # a tight deadline on the minority truncates the majority's turn
    srv.submit(Request(rid=9, prompt=rng.integers(0, 8, 3),
                       max_new_tokens=4, adapter_id="A", slo_ms=3.0))
    assert srv._turn_budget("M", groups) == 3


def test_slo_deadline_preempts_rotation_order(tiny_cfg, tiny_params):
    """When slack runs low, the SLO-carrying group jumps the round-robin
    order (the no-SLO group was submitted first and would otherwise
    rotate in first)."""
    srv = _two_group_server(tiny_cfg, tiny_params, batch_slots=2,
                            max_seq=64, steps_per_turn=4)
    rng = np.random.default_rng(1)
    base = Request(rid=0, prompt=rng.integers(0, 8, 3),
                   max_new_tokens=12)
    slow = Request(rid=1, prompt=rng.integers(0, 8, 3),
                   max_new_tokens=4, adapter_id="A")
    urgent = Request(rid=2, prompt=rng.integers(0, 8, 3),
                     max_new_tokens=4, adapter_id="M", slo_ms=5.0)
    for r in (base, slow, urgent):
        srv.submit(r)
    srv.run_until_drained()
    assert all(r.done for r in (base, slow, urgent))
    assert urgent.finish_step < slow.finish_step


def test_drained_turn_never_shortens_next_group(tiny_cfg, tiny_params):
    """Regression: a group draining mid-turn must leave no stale
    ``_turn_left`` behind — the next scheduled group gets its FULL
    recomputed budget, not the drained group's leftover."""
    srv = _two_group_server(tiny_cfg, tiny_params, batch_slots=1,
                            max_seq=64, steps_per_turn=6)
    rng = np.random.default_rng(2)
    short = Request(rid=0, prompt=rng.integers(0, 8, 2),
                    max_new_tokens=2, adapter_id="A")
    srv.submit(short)
    srv.step()                      # A admitted, emits prime + 1 token
    assert short.done
    # mid-turn drain: the countdown is cleared, not left to leak
    assert srv._turn_left == 0
    long = Request(rid=1, prompt=rng.integers(0, 8, 2),
                   max_new_tokens=8)
    srv.submit(long)
    expected = srv._turn_budget(None, [None])
    srv.step()
    assert srv._turn_left == expected - 1


def test_fairness_9to1_skew_no_starvation(tiny_cfg, tiny_params):
    """9:1 skewed queue: the minority adapter still completes within the
    aging bound, adapter-aware scheduling swaps less than round-robin,
    and all three legs (rr / aware / aware+cache) emit identical token
    streams."""
    new_tokens, spt, aging = 6, 2, 6
    tenancy = ["M"] * 9 + ["m"]
    legs = {}
    for leg, kw in (("rr", dict(adapter_aware=False)),
                    ("aware", dict(adapter_aware=True)),
                    ("cached", dict(adapter_aware=True,
                                    cache_bytes=1 << 24))):
        tunedM = _tuned(tiny_params, rows=(0, 2), scale=0.7, seed=50)
        tunedm = _tuned(tiny_params, rows=(1, 3), scale=0.4, seed=60)
        reg = InMemoryRegistry({
            "M": extract_delta(tiny_params, tunedM,
                               meta={"adapter_id": "M"}),
            "m": extract_delta(tiny_params, tunedm,
                               meta={"adapter_id": "m"}),
        })
        srv = DecodeServer(tiny_cfg, tiny_params, batch_slots=2,
                           max_seq=64, registry=reg, steps_per_turn=spt,
                           aging_steps=aging, **kw)
        reqs = _mixed_requests(tiny_cfg, tenancy, new_tokens=new_tokens)
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        assert all(r.done for r in reqs)
        legs[leg] = dict(srv=srv,
                         outs={r.rid: tuple(r.out) for r in reqs},
                         minority=[r for r in reqs
                                   if r.adapter_id == "m"][0])
    assert legs["aware"]["outs"] == legs["rr"]["outs"]
    assert legs["cached"]["outs"] == legs["rr"]["outs"]
    # worst-case wait is aging + the longest possible turn; add the
    # minority's own service time and a small margin
    bound = aging + 4 * spt + new_tokens + 2
    m = legs["aware"]["minority"]
    assert m.finish_step - m.submit_step <= bound, \
        f"minority starved: {m.finish_step - m.submit_step} > {bound}"
    assert legs["aware"]["srv"].swaps < legs["rr"]["srv"].swaps
    cached = legs["cached"]["srv"]
    assert cached.cache.misses <= 2      # each adapter uploaded once
    assert cached.cache.hits >= 1        # revisits served from HBM


def test_cache_requires_registry(tiny_cfg, tiny_params):
    with pytest.raises(ValueError, match="registry"):
        DecodeServer(tiny_cfg, tiny_params, cache_bytes=1 << 20)
