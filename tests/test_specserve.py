"""SpecServe: self-speculative serving (base drafts, adapter verifies).

Covers the acceptance rule (property: accepted prefix IS the longest
greedy-agreeing prefix), bitwise parity of ``verify_into_slots`` against
per-token ``decode_step`` (dense and paged caches), bit-identical token
streams between speculative and plain serving across the rr/aware/
cached/q8 and dense/paged legs — including mid-stream rejection with
paged page-table rollback — the allocator's ``rollback_to`` invariants,
the supports_spec_decode gate, and adaptive draft-length backoff.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapters import (InMemoryRegistry, extract_delta,
                            quantize_delta)
from repro.adapters.testing import perturb_rows as _tuned
from repro.configs.base import (BLOCK_GLOBAL_ATTN, BLOCK_LOCAL_ATTN,
                                ModelConfig)
from repro.models import model
from repro.runtime.paged_kv import PageAllocator, pages_for
from repro.runtime.serve_loop import DecodeServer, Request, spec_accept
from tests._hyp import given, settings, st

K = jax.random.PRNGKey


# ------------------------------------------------------ acceptance rule


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=0, max_size=8),
       st.lists(st.integers(0, 3), min_size=9, max_size=9))
def test_spec_accept_is_longest_agreeing_prefix(draft, pool):
    """Property: ``accepted`` is EXACTLY the longest prefix where the
    draft agrees with the verifier, and the emitted tokens are the
    verifier's own argmaxes for those positions plus one."""
    verify = pool[:len(draft) + 1]
    a, emitted = spec_accept(draft, verify)
    assert 0 <= a <= len(draft)
    assert all(draft[j] == verify[j] for j in range(a))        # agrees
    assert a == len(draft) or draft[a] != verify[a]            # longest
    assert emitted == [int(t) for t in verify[:a + 1]]
    assert len(emitted) == a + 1                     # >= 1 token/round


def test_spec_accept_requires_n_plus_one_scores():
    with pytest.raises(ValueError):
        spec_accept([1, 2, 3], [1, 2, 3])


# ------------------------------------------- verify-vs-decode parity


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_verify_into_slots_bitwise_matches_decode_step(layout, tiny_cfg,
                                                       tiny_params):
    """One chunked verify dispatch over K positions produces BITWISE
    the same logits and cache rows as K per-token decode steps — the
    property that makes speculative streams identical by construction,
    not within-tolerance."""
    cfg, params = tiny_cfg, tiny_params
    B, max_seq, L, s0, ps = 3, 48, 6, 2, 8
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (B, L)).astype(np.int32)

    if layout == "paged":
        per_slot = max_seq // ps
        table = np.arange(B * per_slot, dtype=np.int32).reshape(
            B, per_slot)
        tbl = jnp.asarray(table)
        kw = dict(page_table=tbl, active=jnp.ones(B, bool))
        mk = lambda: model.init_paged_cache(cfg, B, B * per_slot + 1,
                                            ps, max_seq)
    else:
        tbl, kw = None, {}
        mk = lambda: model.init_cache(cfg, B, max_seq)

    # reference: L per-token decode steps
    cache, pos = mk(), np.zeros(B, np.int64)
    ref = []
    for i in range(L):
        lg, cache = model.decode_step(params, cfg, cache,
                                      jnp.asarray(toks[:, i:i + 1]),
                                      jnp.asarray(pos),
                                      attn_impl="full", **kw)
        ref.append(np.asarray(lg))
        pos += 1

    # candidate: prime to s0 per-token, verify positions s0..L-1 at once
    cache2, pos2 = mk(), np.zeros(B, np.int64)
    for i in range(s0):
        _, cache2 = model.decode_step(params, cfg, cache2,
                                      jnp.asarray(toks[:, i:i + 1]),
                                      jnp.asarray(pos2),
                                      attn_impl="full", **kw)
        pos2 += 1
    vkw = {"page_table": tbl} if layout == "paged" else {}
    vlog, cache2 = model.verify_into_slots(params, cfg, cache2,
                                           jnp.asarray(toks[:, s0:]),
                                           jnp.asarray(pos2),
                                           jnp.ones(B, bool), **vkw)
    vlog = np.asarray(vlog)
    for j in range(L - s0):
        assert np.array_equal(ref[s0 + j], vlog[:, j]), \
            f"{layout} verify logits at offset {j} are not bit-identical"
    # the chunk's K/V rows land bit-identical to the per-token writes
    for a, b in zip(jax.tree.leaves(cache["stages"]),
                    jax.tree.leaves(cache2["stages"])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"{layout} cache rows diverged"


def test_verify_masks_inactive_slots(tiny_cfg, tiny_params):
    """Inactive slots' cache rows pass through bit-exactly and their
    logits are ignored by the server — verify must not scribble."""
    cfg, params = tiny_cfg, tiny_params
    B, max_seq = 2, 32
    cache = model.init_cache(cfg, B, max_seq)
    _, cache = model.decode_step(params, cfg, cache,
                                 jnp.ones((B, 1), jnp.int32),
                                 jnp.zeros(B, jnp.int32),
                                 attn_impl="full")
    act = jnp.asarray([True, False])
    before = [np.asarray(l) for l in jax.tree.leaves(cache["stages"])]
    _, cache2 = model.verify_into_slots(
        params, cfg, cache, jnp.ones((B, 3), jnp.int32),
        jnp.ones(B, jnp.int32), act)
    for pre, post in zip(before,
                         jax.tree.leaves(cache2["stages"])):
        if pre.ndim >= 4:  # K/V rows: [groups, B, S, ...]; slot 1 (the
            # batch axis is 1 — the leading axis stacks layer groups)
            assert np.array_equal(pre[:, 1], np.asarray(post)[:, 1])


def test_supports_spec_decode_gate(tiny_cfg):
    assert model.supports_spec_decode(tiny_cfg)
    local = tiny_cfg.replace(
        pattern=(BLOCK_LOCAL_ATTN, BLOCK_GLOBAL_ATTN), window_size=8)
    assert not model.supports_spec_decode(local)   # ring rollback unsafe
    with pytest.raises(ValueError):
        DecodeServer(local, {}, batch_slots=1, max_seq=16, cache=None,
                     speculate=4)


# ----------------------------------------------- stream parity: server


def _mixed_requests(cfg, tenancy, new_tokens=7, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               3 + (3 * i) % 9),
                    max_new_tokens=new_tokens, adapter_id=t)
            for i, t in enumerate(tenancy)]


def _drain(cfg, params, tenancy, reg, **kw):
    srv = DecodeServer(cfg, params, batch_slots=2, max_seq=64,
                       registry=reg, steps_per_turn=2, **kw)
    reqs = _mixed_requests(cfg, tenancy)
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    assert all(r.done for r in reqs)
    return {r.rid: tuple(r.out) for r in reqs}, srv


def test_spec_stream_parity_across_serving_legs(tiny_cfg, tiny_params):
    """Speculative token streams are bit-identical to plain decoding on
    every serving leg: rr/aware/cached/q8 schedulers, dense and paged
    KV.  The q8 legs compare spec-q8 vs plain-q8 (quantized deltas are
    different weights than fp32)."""
    # mild perturbation: drafts agree often but not always, so both
    # acceptance and mid-stream rejection paths execute
    tunedA = _tuned(tiny_params, rows=(0, 2), scale=0.02, seed=10)
    tunedB = _tuned(tiny_params, rows=(1, 3), scale=0.4, seed=20)
    deltas = {
        "A": extract_delta(tiny_params, tunedA, meta={"adapter_id": "A"}),
        "B": extract_delta(tiny_params, tunedB, meta={"adapter_id": "B"}),
    }
    budget = deltas["A"].nbytes + 64
    tenancy = ["A", "B", None, "B", "A", None]
    legs = {
        "plain": dict(),
        "spec_rr": dict(adapter_aware=False, speculate=3),
        "spec_aware": dict(speculate=3),
        "spec_cached": dict(cache_bytes=budget, speculate=3),
        "plain_q8": dict(q8=True),
        "spec_q8": dict(cache_bytes=budget, q8=True, speculate=3),
        "spec_paged": dict(kv_layout="paged", kv_page_size=8,
                           speculate=3),
    }
    outs, srvs = {}, {}
    for leg, kw in legs.items():
        kw = dict(kw)
        reg = InMemoryRegistry(
            {a: quantize_delta(d) for a, d in deltas.items()}
            if kw.pop("q8", False) else dict(deltas))
        outs[leg], srvs[leg] = _drain(tiny_cfg, tiny_params, tenancy,
                                      reg, **kw)
    for leg in ("spec_rr", "spec_aware", "spec_cached", "spec_paged"):
        assert outs[leg] == outs["plain"], \
            f"{leg} token streams diverged from plain decoding"
    assert outs["spec_q8"] == outs["plain_q8"], \
        "spec q8 streams diverged from plain q8"
    # speculation actually sped things up on the same workload
    assert srvs["spec_aware"].steps < srvs["plain"].steps
    st_ = srvs["spec_aware"].stats()["spec"]
    assert st_["rounds"] > 0 and st_["drafted"] > 0
    assert st_["tokens_per_step"] > 1.0
    assert st_["flips"] >= 2       # adapter groups flipped base<->tenant


def test_spec_midstream_rejection_rolls_back_paged(tiny_cfg,
                                                   tiny_params):
    """A strongly perturbed adapter rejects most drafts mid-stream; the
    paged path must unmap the speculative pages and still emit the
    bit-identical stream."""
    tuned = _tuned(tiny_params, rows=(1, 3), scale=2.0, seed=7)
    reg = InMemoryRegistry(
        {"T": extract_delta(tiny_params, tuned, meta={"adapter_id": "T"})})
    tenancy = ["T", "T", None, "T"]
    plain, _ = _drain(tiny_cfg, tiny_params, tenancy, reg,
                      kv_layout="paged", kv_page_size=8)
    spec, srv = _drain(tiny_cfg, tiny_params, tenancy, reg,
                       kv_layout="paged", kv_page_size=8, speculate=4)
    assert spec == plain, "paged spec streams diverged after rejection"
    st_ = srv.stats()["spec"]
    assert st_["rollbacks"] > 0, "expected mid-stream rejections"
    assert st_["acceptance_rate"] < 1.0
    assert srv.alloc.n_rollback > 0, "no pages were unmapped"
    assert srv.stats()["kv"]["spec_rollback_pages"] > 0


def test_spec_adaptive_draft_len_backs_off(tiny_cfg, tiny_params):
    """Near-zero acceptance halves the per-group draft length; the
    base-tenant group (drafter == verifier) stays at the cap."""
    tuned = _tuned(tiny_params, rows=(1, 3), scale=2.0, seed=7)
    reg = InMemoryRegistry(
        {"T": extract_delta(tiny_params, tuned, meta={"adapter_id": "T"})})
    _, srv = _drain(tiny_cfg, tiny_params, ["T", "T", "T", None, None],
                    reg, speculate=4)
    assert srv._spec_len.get("T", 4) < 4, \
        "draft length did not back off under rejections"
    assert srv._spec_len.get(None, 4) == 4   # base group: 100% accept


# ------------------------------------------------- allocator rollback


def test_rollback_to_unmaps_and_restores_reservation():
    al = PageAllocator(10, 4, slots=2, max_seq=32, share_prefix=False)
    al.admit(0, al.plan(None, [1, 2, 3], 16))
    al.ensure_range(0, 0, 10)                      # 3 pages mapped
    assert al.pages_in_use == 3
    resv0 = int(al._resv[0])
    dropped = al.rollback_to(0, 5)                 # keep rows 0..4
    assert dropped == 1 and al.pages_in_use == 2
    assert int(al.table()[0, 2]) == al.NULL_PAGE
    assert int(al._resv[0]) == resv0 + 1           # reservation restored
    assert al.n_rollback == 1
    # rolled-back range can be re-mapped and re-used
    al.ensure_range(0, 0, 10)
    assert al.pages_in_use == 3
    # keep_rows on a page boundary keeps exactly the full pages
    assert pages_for(8, 4) == 2
    assert al.rollback_to(0, 8) == 1
    # idempotent once the tail is unmapped
    assert al.rollback_to(0, 8) == 0
