"""StragglerMonitor edge cases: the checkpoint_and_exit action and the
fleet-median boundary conditions (single host, all-equal EMAs, warmup
cutoff) that the serve-side ``ReplicaHealth`` inherits via the shared
``ema_update`` / ``flagged_vs_median`` helpers."""
import pytest

from repro.runtime.straggler import (StragglerConfig, StragglerMonitor,
                                     ema_update, flagged_vs_median)


def _timed_step(mon, dt, fleet_emas=None):
    """One monitored step whose wall time is forced to ``dt`` seconds
    (the tests inject timings instead of sleeping)."""
    import time
    mon.step_begin()
    mon._t0 = time.monotonic() - dt
    return mon.step_end(fleet_emas=fleet_emas)


# --------------------------------------------------------------------- #
# shared helpers
# --------------------------------------------------------------------- #


def test_ema_update_seeds_then_blends():
    assert ema_update(None, 4.0, alpha=0.1) == 4.0   # first sample seeds
    assert ema_update(4.0, 8.0, alpha=0.5) == pytest.approx(6.0)
    assert ema_update(4.0, 8.0, alpha=0.0) == pytest.approx(4.0)


def test_flagged_vs_median_upper_median_and_threshold_edge():
    # even-sized fleet: index len//2 picks the UPPER middle value
    assert not flagged_vs_median(4.0, [1.0, 4.0], threshold=2.0)
    assert flagged_vs_median(4.0, [1.0, 1.0, 4.0], threshold=2.0)
    # strictly-greater rule: exactly threshold x median is NOT flagged
    assert not flagged_vs_median(2.0, [1.0, 1.0, 1.0], threshold=2.0)
    assert flagged_vs_median(2.0 + 1e-9, [1.0, 1.0, 1.0], threshold=2.0)
    # degenerate zero median is clamped, not divided by
    assert flagged_vs_median(1.0, [0.0, 0.0, 0.0], threshold=2.0)


def test_single_host_never_flagged():
    mon = StragglerMonitor(StragglerConfig(warmup_steps=1))
    for _ in range(8):
        # no fleet_emas: own EMA is the whole fleet, hence the median
        assert _timed_step(mon, 5.0) == "none"
    assert not mon.flagged


def test_all_equal_emas_never_flag():
    mon = StragglerMonitor(StragglerConfig(warmup_steps=1, ema_alpha=1.0))
    for _ in range(5):
        act = _timed_step(mon, 2.0, fleet_emas=[2.0, 2.0, 2.0, 2.0])
        assert act == "none"
    assert not mon.flagged


def test_warmup_boundary_is_exact():
    cfg = StragglerConfig(warmup_steps=3, ema_alpha=1.0, threshold=2.0)
    mon = StragglerMonitor(cfg)
    slow_fleet = [0.01, 0.01, 0.01, 0.01]
    # steps 1 and 2 are inside warmup: flag suppressed no matter what
    assert _timed_step(mon, 1.0, slow_fleet) == "none"
    assert _timed_step(mon, 1.0, slow_fleet) == "none"
    assert not mon.flagged
    # step 3 == warmup_steps: evaluation starts exactly here
    assert _timed_step(mon, 1.0, slow_fleet) == "skip_data"
    assert mon.flagged


def test_checkpoint_and_exit_returns_evict():
    cfg = StragglerConfig(warmup_steps=1, ema_alpha=1.0,
                          action="checkpoint_and_exit")
    mon = StragglerMonitor(cfg)
    assert _timed_step(mon, 1.0, fleet_emas=[0.01] * 4) == "evict"
    assert mon.flagged


def test_action_none_suppresses_mitigation_but_still_flags():
    cfg = StragglerConfig(warmup_steps=1, ema_alpha=1.0, action="none")
    mon = StragglerMonitor(cfg)
    assert _timed_step(mon, 1.0, fleet_emas=[0.01] * 4) == "none"
    assert mon.flagged          # detection still runs; mitigation off


def test_recovered_host_unflags():
    cfg = StragglerConfig(warmup_steps=1, ema_alpha=1.0, threshold=2.0)
    mon = StragglerMonitor(cfg)
    assert _timed_step(mon, 1.0, fleet_emas=[0.01] * 4) == "skip_data"
    # back to fleet speed: EMA (alpha=1) tracks instantly, flag clears
    assert _timed_step(mon, 0.01, fleet_emas=[0.01] * 4) == "none"
    assert not mon.flagged
