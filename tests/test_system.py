"""End-to-end behaviour: launchers, serving, straggler logic, memory
accounting consistency — the system-level contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import model
from repro.runtime.serve_loop import DecodeServer, Request
from repro.runtime.straggler import StragglerConfig, StragglerMonitor


def test_train_launcher_end_to_end(tmp_path):
    from repro.launch.train import main
    out = main(["--arch", "llama-60m", "--steps", "12", "--batch", "4",
                "--seq", "32", "--optimizer", "blockllm", "--sparsity",
                "0.9", "--reduce", "8", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "6"])
    assert len(out["losses"]) == 12
    # per-step losses on fresh synthetic batches are noise-dominated at
    # 12 reduced-scale steps (observed +-0.03 around 7.63): a strict
    # last<first check flakes on the seed.  Require that optimization
    # moved downhill at all, which is deterministic.
    assert min(out["losses"]) < out["losses"][0]
    import repro.checkpoint.checkpointer as ck
    assert ck.latest_step(tmp_path) == 12


def test_train_launcher_resumes(tmp_path):
    from repro.launch.train import main
    main(["--arch", "llama-60m", "--steps", "6", "--batch", "2", "--seq",
          "32", "--reduce", "8", "--ckpt-dir", str(tmp_path),
          "--ckpt-every", "3"])
    out = main(["--arch", "llama-60m", "--steps", "9", "--batch", "2",
                "--seq", "32", "--reduce", "8", "--ckpt-dir",
                str(tmp_path), "--ckpt-every", "3"])
    assert len(out["losses"]) == 3  # resumed from step 6


def test_serve_launcher():
    from repro.launch.serve import main
    reqs = main(["--arch", "llama-60m", "--reduce", "8", "--slots", "2",
                 "--requests", "3", "--new-tokens", "4",
                 "--max-seq", "32"])
    assert all(len(r.out) == 4 for r in reqs)


def test_decode_server_greedy_matches_forward():
    """Server tokens == argmax over a teacher-forced forward pass."""
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat=False, dtype="float32")
    p = model.init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray([3, 14, 15], np.int32)
    srv = DecodeServer(cfg, p, batch_slots=1, max_seq=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=3)
    srv.submit(req)
    srv.run_until_drained()

    toks = list(prompt)
    for _ in range(3):
        logits, _, _ = model.forward(
            p, cfg, {"tokens": jnp.asarray([toks])}, mode="train",
            attn_impl="full")
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.out == toks[len(prompt):]


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(StragglerConfig(warmup_steps=0, threshold=2.0,
                                           action="skip_data"))
    import time
    mon.step_begin()
    time.sleep(0.05)
    act = mon.step_end(fleet_emas=[0.001, 0.001, 0.001])
    assert act == "skip_data" and mon.flagged


def test_straggler_monitor_quiet_when_normal():
    mon = StragglerMonitor(StragglerConfig(warmup_steps=0, threshold=2.0))
    mon.step_begin()
    act = mon.step_end(fleet_emas=[10.0, 10.0])
    assert act == "none" and not mon.flagged


def test_memory_accounting_matches_live_arrays(tiny_cfg):
    """The analytic accounting used for the paper tables == live bytes."""
    from repro import trainers
    from repro.core.blockllm import BlockLLMConfig
    from repro.core.selection import SelectorConfig
    from repro.models import model as m
    tr = trainers.handle(
        "blockllm", tiny_cfg,
        m.init_params(jax.random.PRNGKey(0), tiny_cfg),
        bcfg=BlockLLMConfig(selector=SelectorConfig(sparsity=0.9,
                                                    policy="static")))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              tiny_cfg.vocab_size)
    tr.train_step({"tokens": toks})
    rep = tr.memory_report()
    live_opt = sum(a.size * a.dtype.itemsize
                   for a in jax.tree.leaves((tr.opt_state.mu,
                                             tr.opt_state.nu)))
    assert rep["opt_state_bytes"] == live_opt
