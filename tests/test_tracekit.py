"""TraceKit: tracer span nesting (incl. across threads), exporter
validity/round-trip, metric instrument semantics, the StepEmitter stdout
contract, the disabled-tracer overhead bound, serve-side bit-identical
token streams tracer on vs off, the compile-skipping ms_per_step EMA,
nested stats() sections + deprecated flat aliases, the opt-in kernel
profiler, and the BlockLLM selection-telemetry helpers."""
import io
import json
import threading
import time
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import selection as sel
from repro.models import model
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       StepEmitter, Tracer, chrome_trace_dict,
                       load_trace_file, write_trace)
from repro.runtime.serve_loop import DecodeServer, Request

K = jax.random.PRNGKey


# ---------------------------------------------------------------- tracer


def test_span_nesting_parent_ids():
    tr = Tracer()
    with tr.span("outer", lane="L") as outer:
        with tr.span("inner", lane="L") as inner:
            pass
        with tr.span("inner2", lane="L"):
            pass
    with tr.span("sibling", lane="L"):
        pass
    by_name = {e.name: e for e in tr.events()}
    assert by_name["outer"].parent_id is None
    assert by_name["inner"].parent_id == outer.span_id
    assert by_name["inner2"].parent_id == outer.span_id
    assert by_name["sibling"].parent_id is None
    assert by_name["inner"].span_id == inner.span_id
    for e in tr.events():
        assert e.t1_ns >= e.t0_ns


def test_span_nesting_is_per_thread():
    """Spans opened on different threads never adopt each other as
    parents; the default lane is the thread name."""
    tr = Tracer()
    barrier = threading.Barrier(2)

    def work(tag):
        with tr.span(f"outer_{tag}"):
            barrier.wait()           # both outers open simultaneously
            with tr.span(f"inner_{tag}"):
                pass

    ts = [threading.Thread(target=work, args=(i,), name=f"w{i}")
          for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    by_name = {e.name: e for e in tr.events()}
    assert len(tr) == 4
    for i in range(2):
        outer, inner = by_name[f"outer_{i}"], by_name[f"inner_{i}"]
        assert outer.parent_id is None
        assert inner.parent_id == outer.span_id     # never cross-thread
        assert outer.lane == inner.lane == f"w{i}"


def test_retroactive_span_and_instant():
    tr = Tracer()
    t0 = Tracer.now()
    time.sleep(0.001)
    with tr.span("open", lane="L"):
        tr.add_span("queue_wait", t0, Tracer.now(), lane="q", rid=7)
        tr.instant("mark", lane="L", step=3)
    qs = tr.spans("queue_wait")[0]
    assert qs.parent_id is None          # retroactive: not on the stack
    assert qs.args == {"rid": 7}
    assert qs.dur_ns > 0
    inst = [e for e in tr.events() if e.kind == "instant"][0]
    assert inst.name == "mark" and inst.args["step"] == 3
    assert set(tr.lanes()) == {"L", "q"}


# -------------------------------------------------------------- exporters


def _demo_tracer():
    tr, reg = Tracer(), MetricsRegistry()
    with tr.span("request", lane="tenant:base", rid=0):
        with tr.span("prefill", lane="tenant:base", chunk=8):
            pass
        tr.instant("jit_compile", lane="sched")
    tr.add_span("queue_wait", tr.t_origin_ns, Tracer.now(), lane="sched",
                arr=np.arange(2))        # non-jsonable arg -> str()
    reg.counter("decode/steps").inc(5)
    reg.gauge("sched/queue_depth").set(2)
    reg.histogram("decode/step_ms").observe(1.5)
    return tr, reg


def test_chrome_trace_schema_and_monotonic_lanes():
    tr, reg = _demo_tracer()
    obj = chrome_trace_dict(tr, reg)
    json.dumps(obj)                       # fully serializable
    evs = obj["traceEvents"]
    lanes_named = {(e["pid"], e["tid"]) for e in evs
                   if e["ph"] == "M" and e["name"] == "thread_name"}
    last = {}
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "M":
            continue
        lane = (e["pid"], e["tid"])
        assert lane in lanes_named
        assert e["ts"] >= last.get(lane, float("-inf"))
        last[lane] = e["ts"]
        if e["ph"] == "X":
            assert e["dur"] >= 0
    # span args survive, with parent/id attached
    pf = [e for e in evs if e["name"] == "prefill"][0]
    assert pf["args"]["chunk"] == 8 and "parent" in pf["args"]
    assert obj["metrics"]["decode/steps"] == 5


def test_exporter_round_trip(tmp_path):
    tr, reg = _demo_tracer()
    pj = write_trace(tmp_path / "t.jsonl", tr, reg)
    recs = load_trace_file(pj)
    assert recs[0] == {"kind": "header", "format": "tracekit.v1",
                       "clock": "monotonic_us"}
    spans = [r for r in recs if r.get("kind") == "span"]
    assert {s["name"] for s in spans} == {"request", "prefill",
                                          "queue_wait"}
    for s in spans:
        assert s["dur_us"] >= 0 and "lane" in s and "ts_us" in s
    req = [s for s in spans if s["name"] == "request"][0]
    pf = [s for s in spans if s["name"] == "prefill"][0]
    assert pf["parent"] == req["id"]
    # the non-jsonable numpy arg was coerced to a string
    qw = [s for s in spans if s["name"] == "queue_wait"][0]
    assert isinstance(qw["args"]["arr"], str)
    mets = {r["name"]: r["value"] for r in recs
            if r.get("kind") == "metric"}
    assert mets["decode/steps"] == 5
    assert mets["decode/step_ms"]["count"] == 1
    # extension dispatch: anything not .jsonl is Chrome format
    pc = write_trace(tmp_path / "t.json", tr, reg)
    evs = load_trace_file(pc)
    assert any(e["name"] == "thread_name" for e in evs)


# ---------------------------------------------------------------- metrics


def test_metric_instrument_semantics():
    reg = MetricsRegistry()
    c = reg.counter("a/n")
    c.inc()
    c.inc(4)
    assert reg.counter("a/n") is c and c.value == 5
    g = reg.gauge("a/g")
    g.set(3)
    g.set(1.5)
    assert g.value == 1.5
    h = reg.histogram("a/h")
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["sum"] == 6.0 and s["min"] == 1.0 \
        and s["max"] == 3.0 and s["p50"] == 2.0
    with pytest.raises(TypeError):
        reg.gauge("a/n")                  # kind mismatch on reuse
    nested = reg.nested()
    assert nested["a"]["n"] == 5 and nested["a"]["g"] == 1.5
    txt = reg.dump_text()
    assert "a/n 5" in txt and "a/h.count 3" in txt


def test_histogram_decimation_bounds_memory():
    h = Histogram("h", cap=64)
    n = 10_000
    for i in range(n):
        h.observe(float(i))
    assert h.count == n and h.min == 0.0 and h.max == float(n - 1)
    assert h.sum == sum(range(n))
    assert len(h._samples) < 64           # buffer stayed bounded
    # percentiles still representative of the full run (not the tail)
    assert h.percentile(50) == pytest.approx(n / 2, rel=0.1)
    assert h.percentile(99) >= 0.9 * n


# ------------------------------------------------------------ StepEmitter


def test_step_emitter_stdout_contract():
    buf = io.StringIO()
    tr, reg = Tracer(), MetricsRegistry()
    em = StepEmitter(log_every=2, tracer=tr, metrics=reg,
                     metrics_every=0, stream=buf)
    for i in range(1, 5):
        em.on_step(i, {"loss": 1.0 / i, "step": i, "sel_q": 0.05,
                       "ms": 2.0})
    lines = buf.getvalue().splitlines()
    # log_every gates stdout only: 2 lines for 4 steps
    assert len(lines) == 2
    assert lines[0].startswith("step 2: loss=0.5000")
    assert "sel_q=0.05" in lines[0] and "ms=2" in lines[0]
    # ... but the tracer and registry saw every step
    assert len([e for e in tr.events()
                if e.name == "train_step_metrics"]) == 4
    assert reg.counter("train/steps").value == 4
    assert reg.histogram("train/step_ms").count == 4
    assert reg.gauge("train/sel_q").value == 0.05
    em.warn("adapter export skipped: no base", start_step=3)
    assert buf.getvalue().splitlines()[-1] == \
        "warning: adapter export skipped: no base"
    warn = [e for e in tr.events() if e.name == "warning"][0]
    assert warn.args["start_step"] == 3


def test_step_emitter_all_sinks_off_is_silent():
    buf = io.StringIO()
    em = StepEmitter(log_every=0, stream=buf)
    em.on_step(1, {"loss": 0.5})
    assert buf.getvalue() == ""


# ----------------------------------------------------- serve integration


def _serve_cfg(vocab=64):
    return ModelConfig(name="tk", family="dense", num_layers=2,
                       d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                       vocab_size=vocab, remat=False)


def _run_serve(cfg, params, tracer=None, metrics=None, n_req=4,
               new_tokens=4, **kw):
    srv = DecodeServer(cfg, params, batch_slots=2, max_seq=32,
                       prefill_chunk=4, tracer=tracer, metrics=metrics,
                       **kw)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 3 + i),
                    max_new_tokens=new_tokens) for i in range(n_req)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    return srv, reqs


def test_tracing_does_not_change_token_streams():
    """The acceptance bar: tracer on vs off is bit-identical."""
    cfg = _serve_cfg()
    params = model.init_params(K(0), cfg)
    _, base_reqs = _run_serve(cfg, params, tracer=None)
    tr = Tracer()
    srv, traced_reqs = _run_serve(cfg, params, tracer=tr,
                                  metrics=MetricsRegistry())
    assert {r.rid: tuple(r.out) for r in traced_reqs} == \
           {r.rid: tuple(r.out) for r in base_reqs}
    names = {e.name for e in tr.events()}
    assert {"submit", "queue_wait", "admit", "prefill", "decode_step",
            "request"} <= names
    # every request got a lifecycle span on its tenant lane
    assert len(tr.spans("request")) == len(traced_reqs)
    assert len(tr.spans("queue_wait")) == len(traced_reqs)


def test_ema_skips_compile_steps_and_stats_sections():
    # distinct vocab -> distinct decode-fn shapes -> the first decode
    # step of THIS test compiles even though the lru-cached decode fn
    # was already warmed by other tests in the process
    cfg = _serve_cfg(vocab=80)
    params = model.init_params(K(0), cfg)
    srv, reqs = _run_serve(cfg, params, metrics=MetricsRegistry(),
                           n_req=5, new_tokens=6, ms_per_step="auto")
    # at least the first decode step compiled; compile-laden samples are
    # excluded from both the EMA and the step_ms histogram
    compiles = srv.metrics.counter("sched/compiles").value
    assert compiles >= 1
    assert srv._ms_samples == srv.steps - compiles
    assert srv._ms_samples >= 1
    assert srv.metrics.histogram("decode/step_ms").count == \
        srv._ms_samples
    s = srv.stats()
    # nested sections sourced from the registry
    assert s["decode"]["steps"] == srv.steps
    assert s["sched"]["finished"] == len(reqs)
    assert s["prefill"]["dispatches"] == srv.prefill_dispatches
    # stats schema v2: the flat aliases are gone, the version is stamped
    assert s["stats_version"] == 2
    for gone in ("steps", "swaps", "swap_bytes", "swap_rate", "applied",
                 "prefill_dispatches", "prefill_prompt_tokens",
                 "ms_per_step"):
        assert gone not in s, f"removed flat alias {gone!r} reappeared"


def test_disabled_tracer_overhead_bound():
    """Tracer-off instrumentation is a handful of ``x is None`` guards
    per decode step.  Bound the measured guard cost against 1% of a
    (very conservative) 1ms decode step."""
    tracer = None
    n = 200_000

    t0 = time.perf_counter()
    acc = 0
    for _ in range(n):
        if tracer is not None:            # the exact hot-path guard
            acc += 1
    per_guard_s = (time.perf_counter() - t0) / n
    guards_per_step = 40                  # >> actual count in step()
    assert per_guard_s * guards_per_step < 0.01 * 1e-3, \
        (f"{guards_per_step} guards cost "
         f"{per_guard_s * guards_per_step * 1e6:.2f}us per step "
         f"(>1% of a 1ms decode step)")


# --------------------------------------------------------- kernel profiler


def test_kernel_profiler_records_and_passthrough():
    from repro.kernels import ops

    q = jax.random.normal(K(1), (1, 128, 2, 16))
    k = jax.random.normal(K(2), (1, 128, 2, 16))
    v = jax.random.normal(K(3), (1, 128, 2, 16))
    ref = ops.flash_attention(q, k, v, interpret=True)   # profiler off
    tr, reg = Tracer(), MetricsRegistry()
    prof = ops.enable_kernel_profiling(tracer=tr, metrics=reg)
    try:
        out = ops.flash_attention(q, k, v, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        assert len(prof.records) == 1
        rec = prof.records[0]
        assert rec["op"] == "flash_attention" and rec["ms"] >= 0
        assert rec["bytes"] == q.nbytes * 2 + k.nbytes + v.nbytes
        assert reg.counter("kernels/flash_attention_calls").value == 1
        spans = tr.spans("flash_attention")
        assert len(spans) == 1 and spans[0].lane == "kernels"
        # inside jit the op must pass through untimed (tracer leaves)
        jitted = jax.jit(lambda a, b, c: ops.flash_attention(
            a, b, c, interpret=True))
        np.testing.assert_allclose(np.asarray(jitted(q, k, v)),
                                   np.asarray(ref), rtol=1e-5,
                                   atol=1e-5)
        assert len(prof.records) == 1     # no record from traced call
        summ = prof.summary()
        assert summ["flash_attention"]["calls"] == 1
    finally:
        ops.disable_kernel_profiling()
    ops.flash_attention(q, k, v, interpret=True)
    assert len(prof.records) == 1         # disabled: no further records


# ------------------------------------------------- selection telemetry


def _plan(leaves=(), stacks=()):
    return SimpleNamespace(
        structure=SimpleNamespace(active_leaves=tuple(leaves)),
        stack_idx={sid: np.asarray(idx) for sid, idx in stacks})


def test_plan_churn_jaccard():
    a = _plan(leaves=("w1", "w2"), stacks=[("s", [0, 1])])
    same = _plan(leaves=("w1", "w2"), stacks=[("s", [0, 1])])
    half = _plan(leaves=("w1", "w3"), stacks=[("s", [0, 2])])
    disjoint = _plan(leaves=("w9",), stacks=[("s", [7])])
    assert sel.plan_churn(None, a) == 1.0
    assert sel.plan_churn(a, same) == 0.0
    assert sel.plan_churn(a, disjoint) == 1.0
    # |a| = |half| = 4, overlap = {w1, s/g0} -> 1 - 2/6
    assert sel.plan_churn(a, half) == pytest.approx(1.0 - 2.0 / 6.0)


def test_norm_concentration():
    flat = {f"u{i}": 1.0 for i in range(10)}
    assert sel.norm_concentration(flat, 0.2) == pytest.approx(0.2)
    spiky = {"hot": 10.0, **{f"u{i}": 1e-3 for i in range(9)}}
    assert sel.norm_concentration(spiky, 0.1) > 0.99
    assert sel.norm_concentration({}, 0.5) == 0.0
    # non-finite (optimistic-init) norms are excluded, not propagated
    with_inf = {"a": float("inf"), "b": 3.0, "c": 4.0}
    assert sel.norm_concentration(with_inf, 1.0) == 1.0
    assert 0.0 < sel.norm_concentration(with_inf, 0.5) < 1.0
