"""TrainerCore protocol conformance, parameterized over every registered
trainer: state_spec honesty, step determinism, memory-report shape, and
bit-identical mid-run checkpoint resume through the ONE generic
train-loop checkpoint path (no trainer-specific serializers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import trainers
from repro.models import model
from repro.optim.adam import Adam
from repro.runtime.train_loop import TrainLoopConfig, run
from repro.trainers.api import TrainerHandle, check_state

K = jax.random.PRNGKey

# +q8 variants are full conformance citizens: same state_spec split,
# bit-identical crash-resume through the generic checkpoint path (int8
# moment leaves + f32 scales ride the ordinary npz payload)
NAMES = ["blockllm", "adam", "galore", "lora", "badam",
         "blockllm+q8", "adam+q8", "badam+q8"]

MEMORY_KEYS = {"params_bytes", "grads_bytes", "opt_state_bytes",
               "mask_bytes", "probe_bytes", "total_train_state"}


def _core(name, cfg):
    return trainers.make(
        name, cfg, adam=Adam(lr=3e-3), lr=3e-3, sparsity=0.9,
        patience=1000, policy="static", k_frac=0.5, rank=4,
        switch_every=50, update_proj_gap=10)


def _batch(cfg, step=0):
    toks = jnp.arange(32)[None, :].repeat(2, 0) % cfg.vocab_size
    return {"tokens": (toks + step) % cfg.vocab_size}


def test_registry_has_all_trainers():
    for name in NAMES:
        assert name in trainers.names()
    with pytest.raises(KeyError, match="unknown trainer"):
        trainers.get("sixth-snowflake")


@pytest.mark.parametrize("name", NAMES)
def test_state_spec_honored(name, tiny_cfg):
    """init and step both produce exactly the declared array/meta split,
    with JSON-able meta and array-only leaves in ``arrays``."""
    core = _core(name, tiny_cfg)
    state = core.init(K(0), model.init_params(K(0), tiny_cfg))
    check_state(core, state)
    state2, metrics = core.step(state, _batch(tiny_cfg))
    check_state(core, state2)
    assert np.isfinite(metrics["loss"])
    assert int(state2.meta["step"]) == 1


@pytest.mark.slow
@pytest.mark.parametrize("name", NAMES)
def test_step_determinism(name, tiny_cfg):
    """Two independent (core, state) pairs from the same seed walk the
    same loss trajectory and end at identical parameters."""
    runs = []
    for _ in range(2):
        core = _core(name, tiny_cfg)
        state = core.init(K(0), model.init_params(K(0), tiny_cfg))
        losses = []
        for i in range(3):
            state, m = core.step(state, _batch(tiny_cfg, i))
            losses.append(m["loss"])
        runs.append((losses, core.merged_params(state)))
    assert runs[0][0] == runs[1][0]
    for a, b in zip(jax.tree.leaves(runs[0][1]), jax.tree.leaves(runs[1][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", NAMES)
def test_memory_report_shape(name, tiny_cfg):
    core = _core(name, tiny_cfg)
    state = core.init(K(0), model.init_params(K(0), tiny_cfg))
    state, _ = core.step(state, _batch(tiny_cfg))
    rep = core.memory_report(state)
    assert set(rep) == MEMORY_KEYS
    assert all(v >= 0 for v in rep.values())
    assert rep["total_train_state"] == sum(
        v for k, v in rep.items()
        if k not in ("params_bytes", "total_train_state"))


@pytest.mark.slow
@pytest.mark.parametrize("name", NAMES)
def test_checkpoint_roundtrip_resumes_bit_identical(name, tmp_path,
                                                    tiny_cfg):
    """6 straight steps == 3 steps + crash + generic restore + 3 steps,
    for EVERY trainer through the one protocol checkpoint path —
    including BlockLLM's host meta (norm dict, plan indices)."""
    def handle():
        core = _core(name, tiny_cfg)
        return TrainerHandle(core,
                             core.init(K(0), model.init_params(K(0),
                                                               tiny_cfg)))

    def batch_fn(step):
        return _batch(tiny_cfg, step)

    hA = handle()
    outA = run(hA, batch_fn, TrainLoopConfig(total_steps=6, ckpt_every=3,
                                             ckpt_dir=None, log_every=0))

    hB = handle()
    with pytest.raises(RuntimeError, match="simulated node failure"):
        run(hB, batch_fn, TrainLoopConfig(
            total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
            log_every=0), crash_at=3)
    hB2 = handle()
    outB = run(hB2, batch_fn, TrainLoopConfig(
        total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path), log_every=0))

    assert hB2.step == 6
    np.testing.assert_array_equal(np.asarray(outA["losses"][3:]),
                                  np.asarray(outB["losses"]))
    for a, b in zip(jax.tree.leaves(hA.merged_params()),
                    jax.tree.leaves(hB2.merged_params())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_blockllm_host_meta_survives_roundtrip(tmp_path, tiny_cfg):
    """The norm dictionary / visit counts / plan indices ride the generic
    manifest meta and come back equal."""
    core = _core("blockllm", tiny_cfg)
    h = TrainerHandle(core, core.init(K(0),
                                      model.init_params(K(0), tiny_cfg)))
    run(h, lambda s: _batch(tiny_cfg, s),
        TrainLoopConfig(total_steps=4, ckpt_every=2,
                        ckpt_dir=str(tmp_path), log_every=0))
    saved_meta = h.state.meta
    core2 = _core("blockllm", tiny_cfg)
    h2 = TrainerHandle(core2, core2.init(K(0),
                                         model.init_params(K(0), tiny_cfg)))
    run(h2, lambda s: _batch(tiny_cfg, s),
        TrainLoopConfig(total_steps=4, ckpt_every=2,
                        ckpt_dir=str(tmp_path), log_every=0))  # resume noop
    assert h2.state.meta["norms"] == saved_meta["norms"]
    assert h2.state.meta["visit_counts"] == saved_meta["visit_counts"]
    assert h2.state.meta["stack_idx"] == saved_meta["stack_idx"]
    assert h2.state.meta["step"] == 4


@pytest.mark.slow
def test_resume_rejects_wrong_trainer(tmp_path, tiny_cfg):
    """A checkpoint written by one trainer must fail fast (clear
    ValueError from the manifest, before any array load) when resumed
    under a different --optimizer."""
    core = _core("blockllm", tiny_cfg)
    h = TrainerHandle(core, core.init(K(0),
                                      model.init_params(K(0), tiny_cfg)))
    run(h, lambda s: _batch(tiny_cfg, s),
        TrainLoopConfig(total_steps=2, ckpt_every=2,
                        ckpt_dir=str(tmp_path), log_every=0))
    core2 = _core("adam", tiny_cfg)
    h2 = TrainerHandle(core2, core2.init(K(0),
                                         model.init_params(K(0),
                                                           tiny_cfg)))
    with pytest.raises(ValueError, match="written by trainer 'blockllm'"):
        run(h2, lambda s: _batch(tiny_cfg, s),
            TrainLoopConfig(total_steps=4, ckpt_every=2,
                            ckpt_dir=str(tmp_path), log_every=0))


def test_carry_surviving_carries_both_moments(tiny_cfg):
    """Satellite fix: re-selection with ``carry_surviving`` must carry
    nu for the same matched rows as mu (not reset it to zeros)."""
    from repro.core.blockllm import BlockLLMConfig
    from repro.core.selection import SelectorConfig
    from repro.trainers.blockllm import BlockLLMCore

    # k_frac=1.0: every row re-selected => guaranteed survivors (the
    # optimistic-init ranking otherwise prefers never-visited rows)
    core = BlockLLMCore(
        tiny_cfg,
        bcfg=BlockLLMConfig(
            selector=SelectorConfig(sparsity=0.9, policy="static",
                                    static_k_frac=1.0, patience=1000),
            carry_surviving=True),
        adam=Adam(lr=3e-3))
    state = core.init(K(0), model.init_params(K(0), tiny_cfg))
    for i in range(2):
        state, _ = core.step(state, _batch(tiny_cfg, i))
    old_idx = {k: list(v) for k, v in state.meta["stack_idx"].items()}
    old_mu = jax.tree.map(np.asarray, state.arrays["opt"].mu)
    old_nu = jax.tree.map(np.asarray, state.arrays["opt"].nu)
    state2 = core.reselect(state)
    carried_any = False
    for sid, new_list in state2.meta["stack_idx"].items():
        common = [g for g in new_list if g in old_idx.get(sid, [])]
        if not common:
            continue
        carried_any = True
        for g in common:
            src = old_idx[sid].index(g)
            dst = new_list.index(g)
            for leaf_old_mu, leaf_old_nu, leaf_mu, leaf_nu in zip(
                    jax.tree.leaves(old_mu["stacks"][sid]),
                    jax.tree.leaves(old_nu["stacks"][sid]),
                    jax.tree.leaves(state2.arrays["opt"].mu["stacks"][sid]),
                    jax.tree.leaves(state2.arrays["opt"].nu["stacks"][sid])):
                np.testing.assert_array_equal(
                    np.asarray(leaf_mu)[dst], leaf_old_mu[src])
                np.testing.assert_array_equal(
                    np.asarray(leaf_nu)[dst], leaf_old_nu[src])
    assert carried_any, "static re-selection kept no surviving rows"
