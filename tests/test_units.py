"""extract/merge/write_back: the differentiable-scatter BCD machinery."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection as sel
from repro.core import units as units_lib


def _setup(tiny_cfg, tiny_params, sparsity=0.8, k_frac=0.5):
    idx = units_lib.build_unit_index(tiny_cfg, tiny_params)
    scfg = sel.SelectorConfig(sparsity=sparsity, policy="static",
                              static_k_frac=k_frac)
    plan, q = sel.select(idx, sel.NormTracker(), sel.VisitTracker(), scfg)
    return idx, plan


def test_extract_merge_roundtrip(tiny_cfg, tiny_params):
    idx, plan = _setup(tiny_cfg, tiny_params)
    active = units_lib.extract_active(tiny_params, idx, plan)
    merged = units_lib.merge_active(tiny_params, idx, plan, active)
    for a, b in zip(jax.tree.leaves(tiny_params), jax.tree.leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_write_back_applies_updates(tiny_cfg, tiny_params):
    idx, plan = _setup(tiny_cfg, tiny_params)
    active = units_lib.extract_active(tiny_params, idx, plan)
    bumped = jax.tree.map(lambda a: a + 1.0, active["sel"])
    new = units_lib.write_back(tiny_params, idx, plan,
                               {"sel": bumped, "probe": active["probe"]})
    # selected rows bumped, unselected untouched
    for sid, idxs in plan.stack_idx.items():
        info = idx.stack(sid)
        old = tiny_params["stages"][info.si][info.pos]
        upd = new["stages"][info.si][info.pos]
        sel_rows = set(np.asarray(idxs).tolist())
        for leaf_old, leaf_new in zip(jax.tree.leaves(old),
                                      jax.tree.leaves(upd)):
            for g in range(leaf_old.shape[0]):
                diff = np.abs(np.asarray(leaf_new[g] - leaf_old[g])).max()
                if g in sel_rows:
                    assert diff > 0.5
                else:
                    assert diff == 0.0


def test_gradients_only_flow_to_active(tiny_cfg, tiny_params):
    idx, plan = _setup(tiny_cfg, tiny_params)
    active = units_lib.extract_active(tiny_params, idx, plan)

    def loss(sel_tree, frozen):
        merged = units_lib.merge_active(frozen, idx, plan,
                                        {"sel": sel_tree, "probe": {}})
        return sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(merged))

    g_sel = jax.grad(loss, argnums=0)(active["sel"], tiny_params)
    # gradient of sum-of-squares == 2 * value for every active leaf
    for g, v in zip(jax.tree.leaves(g_sel), jax.tree.leaves(active["sel"])):
        np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(v),
                                   rtol=1e-5)

    # frozen tree receives NO gradient (stop_gradient prunes it)
    g_frozen = jax.grad(loss, argnums=1)(active["sel"], tiny_params)
    assert all(float(jnp.abs(l).max()) == 0.0
               for l in jax.tree.leaves(g_frozen))


def test_per_row_norms(tiny_cfg, tiny_params):
    idx, plan = _setup(tiny_cfg, tiny_params)
    active = units_lib.extract_active(tiny_params, idx, plan)
    for sid, rows in active["sel"]["stacks"].items():
        sq = units_lib.per_row_sq_norms(rows)
        k = next(k for s, k in plan.structure.k_per_stack if s == sid)
        assert sq.shape == (k,)
        manual = sum(
            np.square(np.asarray(l, np.float64)).reshape(k, -1).sum(1)
            for l in jax.tree.leaves(rows))
        np.testing.assert_allclose(np.asarray(sq, np.float64), manual,
                                   rtol=1e-3)


def test_extract_copies_leaf_units(tiny_cfg, tiny_params):
    """Active leaf units must NOT alias params (donation safety)."""
    idx, plan = _setup(tiny_cfg, tiny_params)
    active = units_lib.extract_active(tiny_params, idx, plan)
    for name, sub in active["sel"]["leaves"].items():
        for a, b in zip(jax.tree.leaves(sub),
                        jax.tree.leaves(tiny_params[name])):
            assert a is not b
