#!/usr/bin/env python
"""CI memory-regression gate: memory_report vs committed baselines.

The paper's contribution IS a memory number — this gate keeps it from
silently regressing.  For every registered trainer core (including the
Q8State ``+q8`` variants) it inits the core on one fixed small arch,
takes ``memory_report``, and compares every byte-count against
``benchmarks/memory_baselines.json``:

- any value growing by more than ``--tolerance`` (default 2%) FAILS;
- a shrink beyond tolerance also fails, with a message telling you to
  re-baseline — improvements should be locked in, not drift back;
- a core missing from the baselines fails (add it deliberately).

Reports are pure functions of array shapes/dtypes (init is
deterministic: fixed seed, fixed arch, static selection), so the gate is
exact and fast — no training steps, no flakiness.

Intentional re-baseline (e.g. a new state group, a smaller codec):

    PYTHONPATH=src python tools/check_memory.py --update
    git add benchmarks/memory_baselines.json   # review the diff!

Usage:  PYTHONPATH=src python tools/check_memory.py [--update]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")

BASELINES = Path(__file__).resolve().parent.parent / "benchmarks" \
    / "memory_baselines.json"

# fixed gate arch: big enough that selection/quantization effects show in
# the byte counts, small enough to init in seconds on a CI runner
GATE_ARCH = dict(name="memgate", family="dense", num_layers=8, d_model=64,
                 num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
                 remat=False)
# fixed hyperparameters — part of the baseline contract; changing them
# requires a deliberate --update
GATE_HYPER = dict(sparsity=0.9, patience=1000, policy="static",
                  k_frac=0.25, rank=8, switch_every=100)


def collect_reports() -> dict:
    import jax
    from repro import trainers
    from repro.configs.base import ModelConfig
    from repro.models import model

    cfg = ModelConfig(**GATE_ARCH)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    reports = {}
    for name in trainers.names():
        core = trainers.make(name, cfg, **GATE_HYPER)
        state = core.init(jax.random.PRNGKey(0), params)
        reports[name] = {k: int(v)
                         for k, v in core.memory_report(state).items()}
    return reports


def compare(reports: dict, baselines: dict, tolerance: float) -> list:
    problems = []
    for name, rep in sorted(reports.items()):
        base = baselines.get(name)
        if base is None:
            problems.append(f"{name}: no committed baseline — run "
                            f"--update and commit the diff")
            continue
        for key, val in sorted(rep.items()):
            ref = base.get(key)
            if ref is None:
                problems.append(f"{name}.{key}: new report key — "
                                f"re-baseline with --update")
                continue
            if ref == 0:
                if val != 0:
                    problems.append(f"{name}.{key}: {val} bytes vs "
                                    f"baseline 0")
                continue
            drift = (val - ref) / ref
            if drift > tolerance:
                problems.append(
                    f"{name}.{key}: {val} bytes is {drift:+.1%} vs "
                    f"baseline {ref} (> {tolerance:.0%} growth)")
            elif drift < -tolerance:
                problems.append(
                    f"{name}.{key}: {val} bytes is {drift:+.1%} vs "
                    f"baseline {ref} — improvement; lock it in with "
                    f"--update")
    for name in sorted(set(baselines) - set(reports)):
        problems.append(f"{name}: baselined core is no longer registered "
                        f"— remove it with --update")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed baselines from the "
                         "current reports")
    ap.add_argument("--tolerance", type=float, default=0.02,
                    help="max allowed relative growth per value")
    ap.add_argument("--baselines", default=str(BASELINES))
    args = ap.parse_args(argv)

    reports = collect_reports()
    path = Path(args.baselines)
    if args.update:
        path.write_text(json.dumps(reports, indent=1, sort_keys=True)
                        + "\n")
        print(f"wrote {path} ({len(reports)} cores)")
        return 0

    if not path.exists():
        print(f"FAIL: no baselines at {path}; run --update and commit")
        return 1
    baselines = json.loads(path.read_text())
    problems = compare(reports, baselines, args.tolerance)
    for name, rep in sorted(reports.items()):
        print(f"{name:14s} opt={rep['opt_state_bytes']:>10d}  "
              f"total={rep['total_train_state']:>10d}")
    if problems:
        print(f"\nFAIL: {len(problems)} memory regression(s):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"\nOK: {len(reports)} cores within {args.tolerance:.0%} of "
          f"baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
