#!/usr/bin/env python
"""CI serving-regression gate: serving benchmarks vs committed baselines.

BlockLLM's <5%-of-params deltas are what make multi-tenant serving
cheap; this gate keeps the serving-side wins from silently regressing
the same way ``check_memory.py`` locks in the training-memory story.
It runs the serving benchmarks in quick mode:

- ``benchmarks/bench_adapter_swap.py``  -> swap_bytes_ratio (tenant
  flip bytes / full reload) and q8_payload_ratio (int8 / fp32 payload),
- ``benchmarks/bench_serve_sched.py``   -> swap_reduction (round-robin
  swaps / adapter-aware+cached swaps), cache_hit_rate, swap_rate_cached,
  h2d_frac (host->device share of flip bytes) and p50/p99 request
  latency in decode steps,
- ``benchmarks/bench_decode_path.py``   -> prefill_dispatch_ratio
  (chunked / per-token priming dispatches), decode_bytes_ratio (fused
  decode-attention cache reads / full-max_seq scoring at a half-full
  cache), ttft_p50 / ttft_p99 time-to-first-token in decode steps,
  plus the PagedKV capacity story: paged_pages_per_token (page-rounding
  overhead over exact per-token KV memory), paged_admitted_ratio (peak
  concurrent requests paged vs dense at equal KV HBM — the bench also
  hard-asserts >= 2x) and paged_prefix_savings (share of prompt tokens
  served from registered prefix pages on a shared-prompt workload),
  plus the SpecServe legs: spec_tokens_per_step (tokens emitted per
  decode round at draft length 4 on repetitive text — the bench
  hard-asserts >= 2x over plain decoding with bit-identical streams)
  and spec_acceptance_rate (tenant-adapter acceptance of base-model
  drafts),
- ``benchmarks/bench_fleet.py``         -> the FleetServe tier:
  fleet_tps_per_round_2 (aggregate tokens per fleet round at 2
  replicas), fleet_tps_speedup_2x / _4x (vs single-replica; the bench
  hard-asserts >= 1.8x at 2 replicas with bit-identical per-tenant
  streams), fleet_p99_latency_rounds, fleet_xrep_bytes (device
  bytes captured cross-replica instead of re-promoted from disk), and
  the ElasticFleet recovery leg: fleet_recover_rounds (rounds from a
  mid-run replica kill to its last replayed request completing — the
  bench hard-asserts zero lost requests and stream parity) and
  fleet_fault_shed (requests shed during failover; baseline 0),

and compares every metric against ``benchmarks/serve_baselines.json``
with a relative tolerance band.  Each metric has an orientation: moving
the BAD way past tolerance fails; moving the GOOD way past tolerance
also fails, with a message telling you to re-baseline — improvements
get locked in, not left to drift back.  The scheduler counters are
deterministic (fixed seeds, greedy decode), so the band only absorbs
cross-version numeric drift in the tiny finetune behind
bench_adapter_swap.

Intentional re-baseline (e.g. a scheduler policy change):

    PYTHONPATH=src python tools/check_serving.py --update
    git add benchmarks/serve_baselines.json   # review the diff!

Usage:  PYTHONPATH=src python tools/check_serving.py [--update]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")

BASELINES = Path(__file__).resolve().parent.parent / "benchmarks" \
    / "serve_baselines.json"

# metric -> "lower" | "higher" (which direction is good)
ORIENTATION = {
    "swap_bytes_ratio": "lower",
    "q8_payload_ratio": "lower",
    "swap_reduction": "higher",
    "cache_hit_rate": "higher",
    "swap_rate_cached": "lower",
    "h2d_frac": "lower",
    "p50_latency_steps": "lower",
    "p99_latency_steps": "lower",
    "prefill_dispatch_ratio": "lower",
    "decode_bytes_ratio": "lower",
    "ttft_p50_steps": "lower",
    "ttft_p99_steps": "lower",
    "paged_pages_per_token": "lower",
    "paged_admitted_ratio": "higher",
    "paged_prefix_savings": "higher",
    "spec_tokens_per_step": "higher",
    "spec_acceptance_rate": "higher",
    "fleet_tps_per_round_2": "higher",
    "fleet_tps_speedup_2x": "higher",
    "fleet_tps_speedup_4x": "higher",
    "fleet_p99_latency_rounds": "lower",
    "fleet_xrep_bytes": "lower",
    "fleet_recover_rounds": "lower",
    "fleet_fault_shed": "lower",
}


def collect_metrics() -> dict:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks import (bench_adapter_swap, bench_decode_path,
                            bench_fleet, bench_serve_sched)

    swap = bench_adapter_swap.run(quick=True)
    sched = bench_serve_sched.run(quick=True)
    decode = bench_decode_path.run(quick=True)
    fleet = bench_fleet.run(quick=True)
    return {
        "fleet_tps_per_round_2": float(fleet["tps_per_round_2"]),
        "fleet_tps_speedup_2x": float(fleet["tps_speedup_2x"]),
        "fleet_tps_speedup_4x": float(fleet["tps_speedup_4x"]),
        "fleet_p99_latency_rounds": float(fleet["p99_latency_rounds"]),
        "fleet_xrep_bytes": float(fleet["xrep_bytes"]),
        "fleet_recover_rounds": float(fleet["recover_rounds"]),
        "fleet_fault_shed": float(fleet["fault_shed"]),
        "prefill_dispatch_ratio": float(
            decode["prefill_dispatch_ratio"]),
        "decode_bytes_ratio": float(decode["decode_bytes_ratio"]),
        "ttft_p50_steps": float(decode["ttft_p50_steps"]),
        "ttft_p99_steps": float(decode["ttft_p99_steps"]),
        "paged_pages_per_token": float(decode["paged_pages_per_token"]),
        "paged_admitted_ratio": float(decode["paged_admitted_ratio"]),
        "paged_prefix_savings": float(decode["paged_prefix_savings"]),
        "spec_tokens_per_step": float(decode["spec_tokens_per_step"]),
        "spec_acceptance_rate": float(decode["spec_acceptance_rate"]),
        "swap_bytes_ratio": float(swap["ratio"]),
        "q8_payload_ratio": float(swap["q8_payload_ratio"]),
        "swap_reduction": float(sched["swap_reduction"]),
        "cache_hit_rate": float(sched["cache_hit_rate"]),
        "swap_rate_cached": float(sched["swap_rate_cached"]),
        "h2d_frac": float(sched["h2d_frac"]),
        "p50_latency_steps": float(sched["p50_latency_steps"]),
        "p99_latency_steps": float(sched["p99_latency_steps"]),
    }


def compare(metrics: dict, baselines: dict, tolerance: float) -> list:
    problems = []
    for key, val in sorted(metrics.items()):
        ref = baselines.get(key)
        if ref is None:
            problems.append(f"{key}: new metric — re-baseline with "
                            f"--update")
            continue
        if ref == 0:
            if abs(val) > tolerance:
                problems.append(f"{key}: {val:.4f} vs baseline 0")
            continue
        drift = (val - ref) / abs(ref)
        worse = drift > tolerance if ORIENTATION[key] == "lower" \
            else drift < -tolerance
        better = drift < -tolerance if ORIENTATION[key] == "lower" \
            else drift > tolerance
        if worse:
            problems.append(
                f"{key}: {val:.4f} is {drift:+.1%} vs baseline "
                f"{ref:.4f} (regression past {tolerance:.0%}, "
                f"{ORIENTATION[key]} is better)")
        elif better:
            problems.append(
                f"{key}: {val:.4f} is {drift:+.1%} vs baseline "
                f"{ref:.4f} — improvement; lock it in with --update")
    for key in sorted(set(baselines) - set(metrics)):
        problems.append(f"{key}: baselined metric no longer reported — "
                        f"remove it with --update")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed baselines from the "
                         "current benchmark outputs")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative tolerance band per metric")
    ap.add_argument("--baselines", default=str(BASELINES))
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the collected metrics as JSON "
                         "(CI uploads this on failure so the debugging "
                         "loop starts from the numbers, not a rerun)")
    args = ap.parse_args(argv)

    metrics = collect_metrics()
    if args.json_out:
        Path(args.json_out).write_text(
            json.dumps(metrics, indent=1, sort_keys=True) + "\n")
    path = Path(args.baselines)
    if args.update:
        path.write_text(json.dumps(metrics, indent=1, sort_keys=True)
                        + "\n")
        print(f"wrote {path} ({len(metrics)} metrics)")
        return 0

    if not path.exists():
        print(f"FAIL: no baselines at {path}; run --update and commit")
        return 1
    baselines = json.loads(path.read_text())
    problems = compare(metrics, baselines, args.tolerance)
    print()
    for key, val in sorted(metrics.items()):
        print(f"{key:22s} {val:10.4f}  (baseline "
              f"{baselines.get(key, float('nan')):10.4f}, "
              f"{ORIENTATION[key]} is better)")
    if problems:
        print(f"\nFAIL: {len(problems)} serving regression(s):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"\nOK: {len(metrics)} serving metrics within "
          f"{args.tolerance:.0%} of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
