#!/usr/bin/env python
"""CI trace-smoke gate: validate TraceKit trace files.

Checks a trace produced by ``launch/serve.py --trace`` or
``launch/train.py --trace`` for schema validity and the span names the
instrumentation contract promises (ISSUE 6 acceptance criteria):

- **Chrome/Perfetto JSON** (non-``.jsonl``): top-level ``traceEvents``
  list; every event carries name/ph/pid/tid/ts; ``ts`` is monotonic
  non-decreasing per (pid, tid) lane; every lane has a ``thread_name``
  metadata record; complete (``X``) events have non-negative ``dur``.
- **JSONL event log**: first line is the ``tracekit.v1`` header; every
  line parses as one object with kind/name; spans carry
  lane/ts_us/dur_us.
- **Required spans**: ``--kind serve`` requires queue_wait, admit,
  prefill, decode_step (plus swap_apply/swap_revert under
  ``--require-swaps``, plus the PagedKV lifecycle instants
  page_alloc/page_free/cow_split/prefix_share under
  ``--require-paging``, plus the SpecServe spec_draft/spec_verify spans
  under ``--require-spec``); ``--kind train`` requires data,
  train_step and per-step ``train_step_metrics`` records carrying the
  BlockLLM
  selection telemetry (sel_q, sel_churn, sel_grad_concentration).

Usage:
    PYTHONPATH=src python tools/check_trace.py --kind serve \
        --require-swaps /tmp/trace_serve.json
    PYTHONPATH=src python tools/check_trace.py --kind serve \
        --require-paging /tmp/trace_paged.json
    PYTHONPATH=src python tools/check_trace.py --kind train \
        /tmp/trace_train.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path

REQUIRED = {
    "serve": ("queue_wait", "admit", "prefill", "decode_step"),
    "train": ("data", "train_step", "train_step_metrics"),
    "any": (),
}
SWAP_SPANS = ("swap_apply", "swap_revert")
PAGING_EVENTS = ("page_alloc", "page_free", "cow_split", "prefix_share")
SPEC_SPANS = ("spec_draft", "spec_verify")
FLEET_EVENTS = ("route", "fleet_round")
FAILOVER_EVENTS = ("fence", "failover")
TRAIN_TELEMETRY = ("sel_q", "sel_churn", "sel_grad_concentration")


def _fail(msg: str) -> None:
    print(f"check_trace: FAIL: {msg}")
    sys.exit(1)


def _check_fleet_processes(path: Path, evs) -> None:
    """A merged fleet trace must carry >= 2 replica processes — one
    Perfetto lane set (pid) per replica, each with its own
    ``process_name`` metadata."""
    procs = {e["pid"]: e["args"].get("name") for e in evs
             if e.get("ph") == "M" and e.get("name") == "process_name"}
    replicas = [n for n in procs.values()
                if n and n.startswith("replica")]
    if len(replicas) < 2:
        _fail(f"{path}: fleet trace needs >= 2 replica processes, "
              f"found {sorted(procs.values())}")


def _load_chrome(path: Path):
    obj = json.loads(path.read_text())
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        _fail(f"{path}: no top-level 'traceEvents' object")
    evs = obj["traceEvents"]
    lanes_named = set()
    last_ts = defaultdict(lambda: float("-inf"))
    names = []
    for i, e in enumerate(evs):
        for k in ("name", "ph", "pid", "tid"):
            if k not in e:
                _fail(f"{path}: event {i} missing {k!r}: {e}")
        if e["ph"] == "M":
            if e["name"] == "thread_name":
                lanes_named.add((e["pid"], e["tid"]))
            continue
        if "ts" not in e:
            _fail(f"{path}: event {i} ({e['name']}) has no ts")
        lane = (e["pid"], e["tid"])
        if e["ts"] < last_ts[lane]:
            _fail(f"{path}: ts not monotonic in lane {lane}: "
                  f"{e['ts']} after {last_ts[lane]} ({e['name']})")
        last_ts[lane] = e["ts"]
        if e["ph"] == "X" and e.get("dur", 0) < 0:
            _fail(f"{path}: negative dur on {e['name']}")
        names.append(e["name"])
        if lane not in lanes_named:
            _fail(f"{path}: lane {lane} used by {e['name']} has no "
                  f"thread_name metadata")
    return names, evs


def _load_jsonl(path: Path):
    lines = [ln for ln in path.read_text().splitlines() if ln]
    if not lines:
        _fail(f"{path}: empty")
    recs = []
    for i, ln in enumerate(lines):
        try:
            recs.append(json.loads(ln))
        except json.JSONDecodeError as e:
            _fail(f"{path}: line {i + 1} is not JSON: {e}")
    head = recs[0]
    if head.get("kind") != "header" or head.get("format") != "tracekit.v1":
        _fail(f"{path}: first line is not a tracekit.v1 header: {head}")
    for i, r in enumerate(recs[1:], start=2):
        if "kind" not in r or "name" not in r:
            _fail(f"{path}: line {i} missing kind/name: {r}")
        if r["kind"] == "span":
            for k in ("lane", "ts_us", "dur_us"):
                if k not in r:
                    _fail(f"{path}: span {r['name']} (line {i}) "
                          f"missing {k!r}")
            if r["dur_us"] < 0:
                _fail(f"{path}: negative dur_us on {r['name']}")
    names = [r["name"] for r in recs[1:]]
    return names, recs


def _check_train_telemetry(path: Path, recs) -> None:
    """JSONL train traces must carry the per-step selection telemetry."""
    steps = [r for r in recs
             if isinstance(r, dict) and r.get("name") == "train_step_metrics"]
    if not steps:
        return  # chrome-format train trace: names check already covers it
    for r in steps:
        args = r.get("args", {})
        missing = [k for k in TRAIN_TELEMETRY if k not in args]
        if missing:
            _fail(f"{path}: train_step_metrics at step "
                  f"{args.get('step')} missing telemetry keys {missing}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--kind", default="any",
                    choices=sorted(REQUIRED),
                    help="which instrumentation contract to enforce")
    ap.add_argument("--require-swaps", action="store_true",
                    help="also require adapter swap spans (multi-tenant "
                         "serve runs)")
    ap.add_argument("--require-paging", action="store_true",
                    help="also require the PagedKV page-lifecycle "
                         "instants (serve runs with --paged)")
    ap.add_argument("--require-spec", action="store_true",
                    help="also require the speculative-decode spans "
                         "(serve runs with --speculate)")
    ap.add_argument("--require-fleet", action="store_true",
                    help="also require the FleetServe router events "
                         "and >= 2 replica processes (merged traces "
                         "from launch.fleet --trace)")
    ap.add_argument("--require-failover", action="store_true",
                    help="also require the ElasticFleet fence/failover "
                         "instants (chaos runs with a fault plan that "
                         "kills or wedges a replica)")
    args = ap.parse_args(argv)

    required = list(REQUIRED[args.kind])
    if args.require_swaps:
        required += list(SWAP_SPANS)
    if args.require_paging:
        required += list(PAGING_EVENTS)
    if args.require_spec:
        required += list(SPEC_SPANS)
    if args.require_fleet:
        required += list(FLEET_EVENTS)
    if args.require_failover:
        required += list(FAILOVER_EVENTS)

    for p in map(Path, args.paths):
        if not p.exists():
            _fail(f"{p}: file not found")
        if p.suffix == ".jsonl":
            names, recs = _load_jsonl(p)
            if args.kind == "train":
                _check_train_telemetry(p, recs)
            if args.require_fleet:
                _fail(f"{p}: --require-fleet needs the merged "
                      f"Chrome-format trace (launch.fleet --trace "
                      f"out.json), not JSONL")
        else:
            names, evs = _load_chrome(p)
            if args.require_fleet:
                _check_fleet_processes(p, evs)
        seen = set(names)
        missing = [n for n in required if n not in seen]
        if missing:
            _fail(f"{p}: required span(s) absent: {missing} "
                  f"(present: {sorted(seen)})")
        print(f"check_trace: OK: {p} ({len(names)} events, "
              f"{len(seen)} distinct names)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
