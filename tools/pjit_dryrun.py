#!/usr/bin/env python
"""CI pjit dry-run smoke: lower + compile one distributed train step.

Closes the ROADMAP "per-core lowerable coverage" follow-up: the full
``launch/dryrun.py`` matrix exercises production archs/meshes with
BlockLLM only, while every other core's distributed path (the generic
``TrainerCore.lowerable`` default — galore, lora, and the Q8State
variants) was never compiled anywhere.  This tool builds the pjit train
setup for ONE registered optimizer on a tiny arch over an 8-device host
mesh and compiles it — seconds per core on a CPU runner, so CI can
afford a matrix leg per optimizer.

Usage:  PYTHONPATH=src python tools/pjit_dryrun.py --optimizer galore
"""
import os

# must precede any jax import: the host platform device count is locked
# at first initialization (same contract as launch/dryrun.py)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--optimizer", default="blockllm")
    ap.add_argument("--mesh", default="4x2",
                    help="data x model axis sizes, e.g. 4x2 or 8x1")
    args = ap.parse_args(argv)

    from repro.configs.base import ModelConfig
    from repro.configs.shapes import ShapeConfig
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_mesh_compat

    cfg = ModelConfig(name="ci-dryrun", family="dense", num_layers=4,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, remat=False, dtype="float32")
    shape = ShapeConfig("ci", seq_len=32, global_batch=8, kind="train")
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh_compat((d, m), ("data", "model"))

    setup = steps_lib.build_train_setup(
        cfg, shape, mesh, optimizer=args.optimizer, sparsity=0.8,
        k_frac=0.5, attn_impl="full")
    lowered = setup.lower()
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    print(f"{args.optimizer}: compiled {setup.name} on {args.mesh} — "
          f"args={ma.argument_size_in_bytes / 2**20:.1f}MiB "
          f"temp={ma.temp_size_in_bytes / 2**20:.1f}MiB")
    return 0


if __name__ == "__main__":
    sys.exit(main())
